"""Vectorized PS addressing vs a dict oracle: batched ensure/lookup/evict
must agree with the per-id dict implementation it replaced, under random
insert/evict/re-insert sequences including arena growth and free-slot
reuse. Plus the regression test pinning the id_of↔slot consistency the
seed's `_ensure` grow path could violate."""

import numpy as np
import pytest

from repro.core.hashmap import EMPTY, IdHashMap
from repro.core.ps import SparseTable

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    given = settings = st = None


class DictOracle:
    """Reference semantics: id -> row value (scalar per id for brevity)."""

    def __init__(self):
        self.rows: dict[int, float] = {}

    def upsert(self, ids, vals):
        for rid, v in zip(ids.tolist(), vals.tolist()):
            self.rows[rid] = v

    def evict(self, ids):
        for rid in np.unique(ids).tolist():
            self.rows.pop(rid, None)


def _check_agrees(t: SparseTable, oracle: DictOracle, probe_ids: np.ndarray):
    live = np.array(sorted(oracle.rows), dtype=np.int64)
    # membership + cardinality
    assert len(t) == len(oracle.rows)
    assert set(t.all_ids().tolist()) == set(oracle.rows)
    if len(live):
        sl = t.lookup(live)
        assert (sl >= 0).all()
        # stable resolution: looking up twice gives the same slots
        np.testing.assert_array_equal(sl, t.lookup(live))
        w, _ = t.gather(live)
        np.testing.assert_allclose(
            w[:, 0], np.array([oracle.rows[r] for r in live.tolist()],
                              np.float32))
    # absent ids resolve to -1 / zero rows
    absent = probe_ids[~np.isin(probe_ids, live)]
    if len(absent):
        assert (t.lookup(absent) == -1).all()
        w, _ = t.gather(absent)
        assert (w == 0).all()


def _run_ops(ops_list):
    t = SparseTable(2, init_capacity=4)
    oracle = DictOracle()
    all_seen = []
    for kind, raw in ops_list:
        ids = np.asarray(raw, dtype=np.int64)
        all_seen.append(ids)
        if kind == "upsert":
            vals = (ids % 1000).astype(np.float32) + 0.5
            t.scatter(ids, np.stack([vals, vals], axis=1))
            # dict semantics: later duplicates win — same as fancy-index
            oracle.upsert(ids, vals)
        elif kind == "evict":
            n_oracle = len([r for r in set(ids.tolist())
                            if r in oracle.rows])
            assert t.evict(ids) == n_oracle
            oracle.evict(ids)
        else:                                     # lookup (pure)
            t.lookup(ids)
    probe = np.unique(np.concatenate(all_seen)) if all_seen else \
        np.empty(0, np.int64)
    _check_agrees(t, oracle, probe)
    return t


@pytest.mark.parametrize("seed", range(5))
def test_random_sequences_match_dict_oracle(seed):
    rng = np.random.default_rng(seed)
    ops_list = []
    for _ in range(40):
        kind = rng.choice(["upsert", "upsert", "evict", "lookup"])
        n = int(rng.integers(1, 200))
        # small id space → heavy re-insert / re-evict collisions
        ids = rng.integers(0, 500, size=n)
        ops_list.append((kind, ids))
    _run_ops(ops_list)


def test_free_slot_reuse_bounds_arena():
    t = SparseTable(4, init_capacity=8)
    a = np.arange(0, 600, dtype=np.int64)
    b = np.arange(1000, 1600, dtype=np.int64)
    t.ensure(a)
    top_after_a = t._top
    assert t.evict(a) == len(a)
    t.ensure(b)                     # must recycle a's slots, not grow
    assert t._top == top_after_a
    assert len(t) == len(b)
    assert (t.lookup(a) == -1).all()
    assert (t.lookup(b) >= 0).all()


def test_hashmap_tombstone_reinsert_and_growth():
    m = IdHashMap(16)
    ids = np.arange(0, 2000, dtype=np.int64) * 7919      # force growth
    m.put(ids, ids % 97)
    assert len(m) == 2000
    m.delete(ids[::2])
    assert len(m) == 1000
    m.put(ids[::2], np.zeros(1000, np.int64))            # tombstone reuse
    assert len(m) == 2000
    np.testing.assert_array_equal(m.lookup(ids[::2]), 0)
    np.testing.assert_array_equal(m.lookup(ids[1::2]), ids[1::2] % 97)


def test_hashmap_negative_and_huge_ids():
    m = IdHashMap()
    ids = np.array([-1, -2**62, 0, 2**62, 17], dtype=np.int64)
    m.put(ids, np.arange(5))
    np.testing.assert_array_equal(m.lookup(ids), np.arange(5))
    assert m.lookup(np.array([1]))[0] == -1


# -- regression: seed `_ensure` could leave _id_of inconsistent when a
# grown slot index skipped entries; the rewrite must keep id_of and the
# id→slot map consistent through interleaved growth + free-list reuse.
def test_id_of_slot_map_consistency_under_growth_and_reuse():
    t = SparseTable(2, init_capacity=4)
    rng = np.random.default_rng(7)
    live = set()
    for round_ in range(30):
        ins = rng.integers(0, 3000, size=rng.integers(1, 120))
        t.ensure(ins)
        live.update(np.unique(ins).tolist())
        if round_ % 3 == 2 and live:
            drop = rng.choice(np.array(sorted(live)),
                              size=max(1, len(live) // 3), replace=False)
            t.evict(drop)
            live.difference_update(drop.tolist())
        # invariant: every live id round-trips id -> slot -> id
        ids = np.array(sorted(live), dtype=np.int64)
        sl = t.lookup(ids)
        assert (sl >= 0).all()
        np.testing.assert_array_equal(t._id_of[sl], ids)
        # and no two live ids share a slot
        assert len(np.unique(sl)) == len(sl)
        # evicted slots are marked unused (sentinel, since -1 is a
        # legal id)
        used = np.zeros(t._w.shape[0], dtype=bool)
        used[sl] = True
        assert (t._id_of[~used] == EMPTY).all()


def test_snapshot_restore_roundtrip_after_churn():
    t = SparseTable(3, ("z", "n"), init_capacity=4)
    ids = np.arange(100, dtype=np.int64)
    w = np.random.default_rng(1).normal(size=(100, 3)).astype(np.float32)
    t.scatter(ids, w, {"z": w + 1, "n": w * w}, step=5)
    t.evict(ids[:50])
    snap = t.snapshot()
    r = SparseTable.restore(snap, 3, ("z", "n"))
    assert set(r.all_ids().tolist()) == set(ids[50:].tolist())
    got, slots = r.gather(ids[50:])
    np.testing.assert_allclose(got, w[50:])
    np.testing.assert_allclose(slots["z"], w[50:] + 1)


if st is not None:
    op_strategy = st.tuples(
        st.sampled_from(["upsert", "evict", "lookup"]),
        st.lists(st.integers(min_value=-50, max_value=200), min_size=1,
                 max_size=60))

    @given(ops_list=st.lists(op_strategy, min_size=1, max_size=25))
    @settings(max_examples=60, deadline=None)
    def test_property_matches_dict_oracle(ops_list):
        _run_ops([(k, np.asarray(v, np.int64)) for k, v in ops_list])
