"""Property-based tests (hypothesis) for the routing/partition invariants
the WeiPS consistency story depends on."""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (dev extra)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import RoutingPlan, reshard_plan

ids_strategy = st.lists(st.integers(min_value=0, max_value=2 ** 62),
                        min_size=1, max_size=200).map(
                            lambda xs: np.asarray(xs, dtype=np.int64))


@given(ids=ids_strategy,
       num_master=st.integers(1, 7),
       num_slave=st.integers(1, 5),
       mult=st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_partition_congruence(ids, num_master, num_slave, mult):
    """partition(id) % num_slave == slave_shard(id): a slave consuming only
    partitions p with p % S == s sees exactly its own IDs — no filtering
    loss, no cross-delivery."""
    plan = RoutingPlan(num_master, num_slave, num_slave * mult)
    part = plan.partition(ids)
    slave = plan.slave_shard(ids)
    np.testing.assert_array_equal(part % num_slave, slave)


@given(ids=ids_strategy, num_master=st.integers(1, 7),
       num_slave=st.integers(1, 5))
@settings(max_examples=50, deadline=None)
def test_split_by_master_is_partition(ids, num_master, num_slave):
    plan = RoutingPlan(num_master, num_slave, num_slave)
    split = plan.split_by_master(np.unique(ids))
    together = np.concatenate(list(split.values())) if split else ids[:0]
    assert sorted(together.tolist()) == sorted(np.unique(ids).tolist())
    for shard, sids in split.items():
        np.testing.assert_array_equal(plan.master_shard(sids), shard)


@given(ids=ids_strategy, src=st.integers(1, 6), dst=st.integers(1, 6))
@settings(max_examples=50, deadline=None)
def test_reshard_plan_is_partition(ids, src, dst):
    """Checkpoint migration N->M shards moves every id exactly once."""
    uniq = np.unique(ids)
    plan = reshard_plan(uniq, src, dst)
    moved = np.concatenate(list(plan.values())) if plan else uniq[:0]
    assert sorted(moved.tolist()) == sorted(uniq.tolist())


@given(ids=ids_strategy)
@settings(max_examples=30, deadline=None)
def test_routing_determinism(ids):
    plan = RoutingPlan(4, 2, 8)
    np.testing.assert_array_equal(plan.partition(ids), plan.partition(ids))


@given(num_slave=st.integers(1, 8), mult=st.integers(1, 3))
@settings(max_examples=30, deadline=None)
def test_partitions_for_slave_cover_exactly(num_slave, mult):
    plan = RoutingPlan(2, num_slave, num_slave * mult)
    all_parts = sorted(
        p for s in range(num_slave) for p in plan.partitions_for_slave(s))
    assert all_parts == list(range(plan.num_partitions))
