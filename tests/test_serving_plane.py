"""Serving-plane subsystem tests (src/repro/serving/): vectorized pull
bit-equality against the seed per-shard loop, lag-bounded replica
selection with failover, serve-cache invalidation by the scatter stream
(upserts and deletes), dense version memoization, micro-batching bucket
padding, multi-scenario isolation, and the bounded feature-admission map.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.weips_ctr import DNN_ADAM, FM_FTRL, LR_FTRL
from repro.core import ClusterConfig, WeiPSCluster
from repro.core.feature_filter import FeatureFilter
from repro.data import ClickStream

FM = dataclasses.replace(FM_FTRL, ftrl_l1=0.01, ftrl_alpha=0.2)
LR = dataclasses.replace(LR_FTRL, ftrl_l1=0.01, ftrl_alpha=0.2)
DNN_SMALL = dataclasses.replace(DNN_ADAM, fields=4, embed_dim=4,
                                dnn_hidden=(16,))


def _train(cl, cfg, steps=15, batch=64, seed=0, space=1 << 12):
    stream = ClickStream(feature_space=space, fields=cfg.fields, seed=seed)
    for i in range(steps):
        ids, y = stream.batch(batch)
        cl.train_on_batch(ids, y, now=float(i))
        cl.sync_tick(float(i))
    return stream


def _seed_serve_rows(cl, ids):
    """The seed's per-group × per-shard masked serving loop, verbatim."""
    b, f = ids.shape
    flat = ids.reshape(-1)
    uniq, inverse = np.unique(flat, return_inverse=True)
    owner = cl.plan.slave_shard(uniq)
    rows = {}
    for group, dim in cl.groups.items():
        vals = np.zeros((len(uniq), dim), np.float32)
        for sid in range(cl.ccfg.num_slave):
            mask = owner == sid
            if mask.any():
                vals[mask] = cl.replica_sets[sid].lookup(group, uniq[mask])
        rows[group] = vals[inverse].reshape(b, f, dim)
    return rows


def _seed_pull_rows(cl, ids):
    """The seed's training-plane masked pull loop, verbatim."""
    b, f = ids.shape
    uniq, inverse = np.unique(ids.reshape(-1), return_inverse=True)
    by_master = cl.plan.split_by_master(uniq)
    rows = {}
    for group, dim in cl.groups.items():
        vals = np.zeros((len(uniq), dim), np.float32)
        for mid, mids in by_master.items():
            pos = np.searchsorted(uniq, mids)
            vals[pos] = cl.masters[mid].pull(group, mids)
        rows[group] = vals[inverse].reshape(b, f, dim)
    return rows


def _direct_replica_rows(cl, ids, replica_idx=0):
    """Ground truth: read straight off one replica per shard, no cache."""
    flat = ids.reshape(-1)
    owner = cl.plan.slave_shard(flat)
    out = {}
    for g, dim in cl.groups.items():
        vals = np.zeros((len(flat), dim), np.float32)
        for sid in range(cl.ccfg.num_slave):
            mask = owner == sid
            if mask.any():
                vals[mask] = cl.replica_sets[sid].replicas[
                    replica_idx].lookup(g, flat[mask])
        out[g] = vals.reshape(ids.shape + (dim,))
    return out


# ---------------------------------------------------------------------------
# vectorized pull == seed loop
# ---------------------------------------------------------------------------
def test_vectorized_serve_pull_matches_seed_loop():
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=3, num_slave=2, num_replicas=2, num_partitions=4))
    stream = _train(cl, FM)
    ids, _ = stream.batch(64)
    seed = _seed_serve_rows(cl, ids)
    got = cl.serve_rows(ids)
    assert set(got) == set(seed)
    for g in seed:
        np.testing.assert_array_equal(got[g], seed[g])


def test_training_pull_matches_seed_loop():
    """The training plane runs the same shared router — bit-equal to the
    seed's per-master masked loop."""
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=3, num_slave=2, num_replicas=1, num_partitions=4))
    stream = _train(cl, FM, steps=8)
    ids, _ = stream.batch(64)
    seed = _seed_pull_rows(cl, ids)
    got, uniq, inverse = cl._pull_rows(ids)
    for g in seed:
        np.testing.assert_array_equal(got[g], seed[g])


# ---------------------------------------------------------------------------
# serve cache: hits skip shard pulls, invalidation keeps reads bit-equal
# ---------------------------------------------------------------------------
def test_cache_hits_skip_shard_pulls():
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=1, num_partitions=4))
    stream = _train(cl, FM)
    ids, _ = stream.batch(64)
    p1 = cl.predict(ids)
    pulled = cl.serving.shard_pulled_rows
    p2 = cl.predict(ids)                      # same ids: all cache hits
    assert cl.serving.shard_pulled_rows == pulled
    cache = cl.serving.scenario().cache
    assert cache.hits > 0 and cache.hit_rate > 0
    np.testing.assert_array_equal(p1, p2)


def test_cache_reads_bit_equal_after_every_sync_tick():
    """The acceptance criterion: after EVERY sync_tick, cached serve reads
    equal direct replica reads bit-for-bit — streamed upserts invalidate
    the rows they rewrote before any predictor can read them stale."""
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=2, num_partitions=4))
    stream = ClickStream(feature_space=1 << 10, fields=FM.fields, seed=3)
    eval_ids, _ = stream.batch(48)
    for i in range(10):
        ids, y = stream.batch(48)
        cl.train_on_batch(ids, y, now=float(i))
        cl.sync_tick(float(i))
        got = cl.serve_rows(eval_ids)         # fills/refreshes the cache
        direct = _direct_replica_rows(cl, eval_ids)
        for g in direct:
            np.testing.assert_array_equal(got[g], direct[g])
    assert cl.serving.scenario().cache.invalidated > 0


def test_cache_invalidation_on_streamed_delete():
    cl = WeiPSCluster(LR, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=1, num_partitions=4,
        feature_ttl_steps=2))
    stream = ClickStream(feature_space=1 << 10, fields=LR.fields, seed=1)
    ids0, y0 = stream.batch(32)
    cl.train_on_batch(ids0, y0, now=0.0)
    cl.sync_tick(0.0)
    rows0 = cl.serve_rows(ids0)               # cache the soon-stale rows
    assert np.abs(rows0["w"]).max() > 0
    for i in range(1, 8):
        ids, y = stream.batch(32)
        cl.train_on_batch(ids, y, now=float(i))
    n_expired = cl.expire_features(now=8.0)
    assert n_expired > 0
    cl.sync_tick(8.0)                         # streams the deletes
    cache = cl.serving.scenario().cache
    assert cache.invalidated > 0
    got = cl.serve_rows(ids0)
    direct = _direct_replica_rows(cl, ids0)
    np.testing.assert_array_equal(got["w"], direct["w"])


# ---------------------------------------------------------------------------
# dense memoization (satellite: _serve_dense re-pull fix)
# ---------------------------------------------------------------------------
def test_dense_cache_memoizes_by_version():
    cl = WeiPSCluster(DNN_SMALL, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2))
    stream = _train(cl, DNN_SMALL, steps=3, batch=32, space=1 << 10)
    ids, _ = stream.batch(16)
    dc = cl.serving.scenario().dense_cache
    cl.predict(ids)
    r0 = dc.refreshes
    assert r0 > 0
    for _ in range(3):                        # no new dense versions
        cl.predict(ids)
    assert dc.refreshes == r0, "dense tensors re-pulled without a new version"
    # a new dense push + sync moves the version → exactly one refresh per
    # tensor that changed
    ids2, y2 = stream.batch(32)
    cl.train_on_batch(ids2, y2, now=10.0)
    cl.sync_tick(10.0)
    cl.predict(ids)
    assert dc.refreshes > r0
    # and the memoized dense bank matches the replica's decoded tensors
    dense = cl._serve_dense()
    rep = cl.replica_sets[0].replicas[0]
    import repro.models.ctr as ctr_model
    for name, shape in ctr_model.dense_shapes(DNN_SMALL).items():
        np.testing.assert_array_equal(
            dense[name], rep.dense[name].reshape(shape))


# ---------------------------------------------------------------------------
# lag-bounded replica selection + failover
# ---------------------------------------------------------------------------
def test_lag_bounded_replica_skip_and_failover():
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=2, num_partitions=4,
        serve_max_lag=0))
    stream = _train(cl, FM, steps=10)
    ids, y = stream.batch(64)
    cl.train_on_batch(ids, y, now=20.0)
    cl.sync_tick(20.0, scatter=False)         # push only: all replicas lag
    fresh = []
    for rs in cl.replica_sets:                # catch up ONE replica per set
        r0 = rs.replicas[0]
        for sc in cl.scatters:
            if sc.shard is r0:
                sc.poll()
        fresh.append(r0)
        assert rs.replica_lag(rs.replicas[1]) > 0
    skips0 = cl.serving.metrics()["replica_lag_skips"]
    got = cl.serve_rows(ids)
    assert cl.serving.metrics()["replica_lag_skips"] > skips0
    # the values served are the FRESH replicas' values
    flat = ids.reshape(-1)
    owner = cl.plan.slave_shard(flat)
    for g, dim in cl.groups.items():
        direct = np.zeros((len(flat), dim), np.float32)
        for sid in range(2):
            mask = owner == sid
            direct[mask] = fresh[sid].lookup(g, flat[mask])
        np.testing.assert_array_equal(got[g].reshape(-1, dim), direct)
    # kill the fresh replicas: serving falls back to the stale ones
    # (availability over freshness) without raising
    for rs in cl.replica_sets:
        rs.replicas[0].kill()
    cl.serving.invalidate_all()               # cached fresh values aside
    cl.predict(ids)
    assert sum(rs.failovers for rs in cl.replica_sets) >= 0


# ---------------------------------------------------------------------------
# micro-batching scheduler
# ---------------------------------------------------------------------------
def test_scheduler_bucket_padding_correctness():
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2,
        serve_buckets=(8, 32)))
    stream = _train(cl, FM, steps=8)
    ids, _ = stream.batch(50)                 # 50 → one 32-chunk + pad(18→32)
    p = cl.predict(ids)
    assert p.shape == (50,)
    # reference: raw predict fn on the exact unpadded rows
    rows = cl.serve_rows(ids)
    dense = cl._serve_dense()
    ref = np.asarray(cl.serving.scenario().predict_raw(
        {g: jnp.asarray(v) for g, v in rows.items()},
        {k: jnp.asarray(v) for k, v in dense.items()}))
    np.testing.assert_allclose(p, ref, rtol=1e-6, atol=1e-7)
    stats = cl.serving.scenario().scheduler.stats
    assert stats.batches == 2 and stats.padded_examples == 14
    assert set(stats.bucket_counts) <= {8, 32}


def test_scheduler_coalesces_concurrent_requests():
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2,
        serve_buckets=(64,)))
    stream = _train(cl, FM, steps=8)
    reqs = [stream.batch(n)[0] for n in (5, 17, 30)]
    singles = [cl.predict(r) for r in reqs]
    tickets = [cl.serving.submit(r) for r in reqs]
    batches0 = cl.serving.scenario().scheduler.stats.batches
    outs = cl.serving.flush()
    # 52 coalesced examples fit ONE 64-bucket execution
    assert cl.serving.scenario().scheduler.stats.batches == batches0 + 1
    for t, r, s in zip(tickets, reqs, singles):
        assert outs[t].shape == (len(r),)
        np.testing.assert_allclose(outs[t], s, rtol=1e-6, atol=1e-7)


def test_predict_does_not_orphan_submitted_tickets():
    """predict() must not consume (and discard) requests admitted via
    submit() — their tickets stay valid for the next flush()."""
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2))
    stream = _train(cl, FM, steps=6)
    ids1, _ = stream.batch(12)
    ids2, _ = stream.batch(20)
    t = cl.serving.submit(ids1)
    p2 = cl.predict(ids2)                     # independent immediate path
    outs = cl.serving.flush()
    assert len(outs) == 1 and outs[t].shape == (12,)
    np.testing.assert_allclose(outs[t], cl.predict(ids1),
                               rtol=1e-6, atol=1e-7)
    assert p2.shape == (20,)


def test_cache_evict_log_stays_bounded():
    """Stream invalidations must not grow the cache table's eviction log
    (delta-checkpoint machinery a cache never uses)."""
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2))
    stream = ClickStream(feature_space=1 << 8, fields=FM.fields, seed=4)
    eval_ids, _ = stream.batch(32)
    for i in range(12):
        ids, y = stream.batch(32)
        cl.train_on_batch(ids, y, now=float(i))
        cl.serve_rows(eval_ids)               # cache rows, then the next
        cl.sync_tick(float(i))                # tick invalidates overlaps
    cache = cl.serving.scenario().cache
    assert cache.invalidated > 0
    assert len(cache.table._evict_log) == 0


def test_dense_cache_stable_across_round_robin_replicas():
    """With 2 replicas round-robin-picked, a lagging replica must neither
    force a refresh per predict nor regress served dense weights."""
    cl = WeiPSCluster(DNN_SMALL, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=2, num_partitions=2))
    stream = _train(cl, DNN_SMALL, steps=3, batch=32, space=1 << 10)
    ids, _ = stream.batch(16)
    # push a dense update, let only replica 0 apply it
    ids2, y2 = stream.batch(32)
    cl.train_on_batch(ids2, y2, now=10.0)
    cl.sync_tick(10.0, scatter=False)
    r0 = cl.replica_sets[0].replicas[0]
    for sc in cl.scatters:
        if sc.shard is r0:
            sc.poll()
    cl.predict(ids)
    cl.predict(ids)                           # both replicas seen once
    p_ref = cl.predict(ids)
    dc = cl.serving.scenario().dense_cache
    r = dc.refreshes
    for _ in range(4):                        # alternating replica picks
        np.testing.assert_array_equal(cl.predict(ids), p_ref)
    assert dc.refreshes == r, "round-robin picks defeated the memoization"


# ---------------------------------------------------------------------------
# multi-scenario registry
# ---------------------------------------------------------------------------
def test_multi_scenario_isolation():
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=1, num_partitions=4))
    lr = dataclasses.replace(LR, name="lr-head")
    cl.add_scenario(lr)
    assert set(cl.serving.registry.names()) == {"lr-head", FM.name}
    assert set(cl.scheduler.scenarios(FM.name)) == {"lr-head", FM.name}
    stream = _train(cl, FM)
    ids, _ = stream.batch(64)
    # the LR scenario reads ONLY the shared "w" group off the FM store
    rows = cl.serve_rows(ids, scenario="lr-head")
    assert set(rows) == {"w"}
    p_lr = cl.predict(ids, scenario="lr-head")
    ref = 1.0 / (1.0 + np.exp(-rows["w"][..., 0].sum(axis=1,
                                                     dtype=np.float64)))
    np.testing.assert_allclose(p_lr, ref, rtol=1e-5, atol=1e-6)
    # cache namespaces are per scenario: widths and counters independent
    fm_cache = cl.serving.scenario(FM.name).cache
    lr_cache = cl.serving.scenario("lr-head").cache
    assert fm_cache.width == 1 + FM.embed_dim and lr_cache.width == 1
    assert fm_cache.stats() != lr_cache.stats() or len(fm_cache) == 0
    cl.predict(ids)                           # FM traffic
    assert cl.serving.scenario(FM.name).examples > 0
    assert cl.serving.scenario("lr-head").examples == 64


def test_scenario_group_validation():
    cl = WeiPSCluster(FM, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2))
    with pytest.raises(ValueError, match="not in the parameter store"):
        cl.add_scenario(DNN_SMALL)            # "emb" is not an FM group
    fm_wide = dataclasses.replace(FM, name="fm-wide", embed_dim=32)
    with pytest.raises(ValueError, match="dim"):
        cl.add_scenario(fm_wide)              # "v" dim mismatch


# ---------------------------------------------------------------------------
# feature-filter admission map stays bounded (satellite)
# ---------------------------------------------------------------------------
def test_feature_filter_counts_bounded():
    f = FeatureFilter(min_count=2, max_tracked=1000)
    rng = np.random.default_rng(0)
    for i in range(20):                       # 20k distinct junk ids
        f.admit(rng.integers(0, 1 << 40, size=1000))
    assert f.trims > 0
    assert len(f.counts) <= 2000              # bounded by traffic/trim, not
    #                                           the lifetime id space
    # a genuinely recurring id still gets admitted
    hot = np.full(1, 12345, np.int64)
    admitted = False
    for _ in range(4):
        admitted = admitted or 12345 in f.admit(np.repeat(hot, 2))
    assert admitted


def test_feature_filter_cross_batch_recurrence_survives_trims():
    """Ids recurring ONCE per batch (never twice within one) must still
    reach admission while junk churns through the bounded map — trims
    may not zero out cross-batch progress when the bound is sized above
    the per-trim-interval distinct traffic."""
    f = FeatureFilter(min_count=5, max_tracked=1000)
    rng = np.random.default_rng(1)
    hot = np.arange(50, dtype=np.int64)       # recurs once per batch
    admitted: set = set()
    for i in range(15):
        junk = rng.integers(1 << 20, 1 << 40, size=80)
        admitted |= set(f.admit(np.concatenate([hot, junk])).tolist())
    assert set(hot.tolist()) <= admitted
    assert len(f.counts) <= 2000


# ---------------------------------------------------------------------------
# LM serve driver: generate must not stack previous calls (satellite)
# ---------------------------------------------------------------------------
def test_serve_driver_generate_resets_between_calls():
    from repro.configs import get_config, reduced
    from repro.serving.predictor import ServeDriver
    from repro.models import init_params
    cfg = reduced(get_config("qwen2-1.5b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    drv = ServeDriver(cfg=cfg, params=params, batch=2, max_len=16)
    tok = jnp.zeros((2, 1), jnp.int32)
    out1 = drv.generate(tok, steps=3)
    assert out1.shape == (2, 3)
    out2 = drv.generate(tok, steps=4)
    assert out2.shape == (2, 4), \
        "second generate stacked the first call's tokens"
    # hot swap between calls still works on the same cache
    drv.hot_swap(params)
    assert drv.generate(tok, steps=2).shape == (2, 2)


def test_cache_lookup_device_counts_misses_off_found_mask():
    """``ServeCache.lookup_device`` (pallas path): hits/misses come off
    the kernel's found mask — no host re-probe — and land in the SAME
    lifetime + window counters the host path feeds, so hit-rate SLOs and
    window stats are backend-agnostic. LRU touch stamps only hit slots."""
    from repro.serving.cache import ServeCache

    cache = ServeCache({"w": 3}, backend="pallas")
    ids = np.arange(1, 65, dtype=np.int64)
    # fully cold: short-circuit, no device probe, all misses
    block, hit = cache.lookup_device(ids)
    assert block is None and not hit.any()
    assert cache.misses == len(ids) and cache.hits == 0
    cache.fill(ids, np.arange(64 * 3, dtype=np.float32).reshape(64, 3))
    mixed = np.concatenate([ids[:16], np.arange(1000, 1016,
                                                dtype=np.int64)])
    block, hit = cache.lookup_device(mixed)
    assert hit[:16].all() and not hit[16:].any()
    assert cache.hits == 16 and cache.misses == len(ids) + 16
    np.testing.assert_array_equal(
        np.asarray(block)[:16],
        np.arange(16 * 3, dtype=np.float32).reshape(16, 3))
    np.testing.assert_array_equal(np.asarray(block)[16:], 0.0)
    # window counters see the same deltas as the host path would
    w = cache.window_stats()
    assert w["hits"] == 16 and w["misses"] == len(ids) + 16
    # LRU: hit slots were touched this tick, the rest stay older
    sl = cache.table.lookup(ids)
    assert (cache.table.last_touch[sl[:16]] == cache._tick).all()
    assert (cache.table.last_touch[sl[16:]] < cache._tick).all()
