"""Deterministic overload + staleness tests for the SLO machinery (ISSUE
8): admission control in the predict scheduler, the shared
PercentileRing, event→deployed staleness through Scatter, per-window
cache counters, and the closed-loop harness under a ManualClock — every
latency and staleness figure here is exact simulated seconds."""

import numpy as np
import pytest

from repro.core.downgrade import SmoothedThresholdTrigger
from repro.core.monitor import ManualClock, PercentileRing
from repro.serving.cache import DenseCache, ServeCache
from repro.serving.scheduler import AdmissionConfig, PredictScheduler


def _echo_runner(ids, bucket):
    """Predict stub: returns each example's first id as the score —
    makes results attributable to their request."""
    return ids[:, 0].astype(np.float32)


def _req(base, n=4, fields=2):
    return np.full((n, fields), base, dtype=np.int64)


def make_sched(clock, max_pending=None, deadline=None):
    return PredictScheduler(
        _echo_runner, buckets=(4, 8, 16, 32),
        admission=AdmissionConfig(max_pending=max_pending,
                                  deadline=deadline),
        clock=clock)


# ---------------------------------------------------------------------------
# PercentileRing
# ---------------------------------------------------------------------------
class TestPercentileRing:
    def test_percentiles_and_wraparound(self):
        r = PercentileRing(size=8)
        r.record(np.arange(100, dtype=np.float64))  # keeps 92..99
        assert len(r) == 8
        assert r.count == 100
        assert list(r.values()) == [92, 93, 94, 95, 96, 97, 98, 99]
        assert r.percentiles((50,))["p50"] == pytest.approx(95.5)

    def test_scalar_and_chunked_records_match_bulk(self):
        a, b = PercentileRing(size=16), PercentileRing(size=16)
        vals = np.arange(40, dtype=np.float64)
        a.record(vals)
        for i, v in enumerate(vals):
            (b.record(v) if i % 3 else b.record([v]))
        assert np.array_equal(a.values(), b.values())

    def test_merged_percentiles(self):
        a, b = PercentileRing(4), PercentileRing(4)
        a.record([1.0, 2.0])
        b.record([100.0, 200.0])
        merged = PercentileRing.merged_percentiles([a, b], (50, 99))
        assert merged["p50"] == pytest.approx(51.0)
        assert merged["p99"] > 100

    def test_empty_ring(self):
        r = PercentileRing(4)
        assert r.percentiles() == {"p50": 0.0, "p99": 0.0}
        assert PercentileRing.merged_percentiles([r]) \
            == {"p50": 0.0, "p99": 0.0}

    def test_reset(self):
        r = PercentileRing(4)
        r.record([5.0, 6.0])
        r.reset()
        assert len(r) == 0
        assert r.percentiles()["p99"] == 0.0

    def test_trigger_duck_typing(self):
        """SmoothedThresholdTrigger fires on a latency ring's p99 exactly
        as it fires on an evaluator's logloss — same percentile
        machinery for the harness and the domino downgrade."""
        trig = SmoothedThresholdTrigger(metric="p99", threshold=0.5,
                                        window=10, min_points=5)
        ring = PercentileRing(32)
        ring.record([0.01] * 8)             # healthy latencies
        assert not trig.check(ring)
        ring.record([2.0] * 8)              # overload tail
        assert trig.check(ring)


# ---------------------------------------------------------------------------
# admission control (deterministic, ManualClock)
# ---------------------------------------------------------------------------
class TestAdmission:
    def test_zero_sheds_below_depth_bound(self):
        clk = ManualClock()
        s = make_sched(clk, max_pending=16)
        for i in range(4):                   # 16 examples == the bound
            s.submit(_req(i))
        out = s.flush()
        assert s.adm.shed_requests == 0
        assert all(p is not None for p in out)
        assert s.adm.executed_requests == 4

    def test_shed_drops_oldest_first(self):
        clk = ManualClock()
        s = make_sched(clk, max_pending=8)   # room for 2 live requests
        for i in range(4):
            s.submit(_req(i))
        out = s.flush()
        # tickets 0 and 1 (oldest) shed; 2 and 3 executed
        assert out[0] is None and out[1] is None
        assert float(out[2][0]) == 2.0 and float(out[3][0]) == 3.0
        assert s.adm.shed_depth_requests == 2

    def test_newest_request_always_admitted(self):
        clk = ManualClock()
        s = make_sched(clk, max_pending=2)   # below even one request
        s.submit(_req(7))
        out = s.flush()
        assert s.adm.shed_requests == 0
        assert float(out[0][0]) == 7.0

    def test_counters_balance_offered(self):
        clk = ManualClock()
        s = make_sched(clk, max_pending=12, deadline=1.0)
        rng = np.random.default_rng(0)
        for i in range(25):
            s.submit(_req(i, n=int(rng.integers(1, 6))))
            if i % 4 == 3:
                clk.advance(0.7)
                s.flush(budget=8)
        clk.advance(5.0)
        s.flush()                            # drain everything left
        a = s.adm
        assert a.executed_requests + a.shed_requests == a.offered_requests
        assert a.executed_examples + a.shed_examples == a.offered_examples
        assert s.pending_examples == 0

    def test_deadline_shed(self):
        clk = ManualClock()
        s = make_sched(clk, deadline=1.0)
        s.submit(_req(0))
        clk.advance(2.0)                     # ticket is now 2s old
        s.submit(_req(1))
        out = s.flush()
        assert out[0] is None
        assert float(out[1][0]) == 1.0
        assert s.adm.shed_deadline_requests == 1

    def test_budgeted_flush_leaves_remainder_pending(self):
        clk = ManualClock()
        s = make_sched(clk)
        for i in range(3):
            s.submit(_req(i))                # 12 examples
        out = s.flush(budget=8)
        assert len(out) == 2                 # 2 requests fit the budget
        assert s.pending_examples == 4
        out2 = s.flush()
        assert float(out2[0][0]) == 2.0

    def test_budget_progress_guarantee(self):
        clk = ManualClock()
        s = make_sched(clk)
        s.submit(_req(0, n=10))              # larger than the budget
        out = s.flush(budget=4)
        assert out[0] is not None and len(out[0]) == 10

    def test_queueing_latency_is_simulated_seconds(self):
        clk = ManualClock()
        s = make_sched(clk)
        s.submit(_req(0))
        clk.advance(3.0)
        s.submit(_req(1))
        clk.advance(1.0)
        s.flush()
        lat = sorted(s.latency.values())
        assert lat == [pytest.approx(1.0), pytest.approx(4.0)]

    def test_no_admission_default_unchanged(self):
        """Without an AdmissionConfig the scheduler behaves exactly like
        the pre-admission one: everything queues, everything executes."""
        s = PredictScheduler(_echo_runner, buckets=(4, 8))
        for i in range(50):
            s.submit(_req(i))
        out = s.flush()
        assert len(out) == 50 and all(p is not None for p in out)
        assert s.adm.shed_requests == 0


# ---------------------------------------------------------------------------
# closed-loop overload (harness + ManualClock)
# ---------------------------------------------------------------------------
def _harness(max_pending, **kw):
    from repro.launch.slo import SLOConfig, SLOHarness
    clk = ManualClock()
    cfg = SLOConfig(rows=1 << 10, fields=4, req_batch=16, budget=64,
                    train_events=32, warmup_ticks=2, measure_ticks=6,
                    max_pending=max_pending, num_master=1, num_slave=1,
                    num_replicas=1, lr_head=False, feedback_delay=0.2,
                    join_window=1.0, seed=3, **kw)
    return SLOHarness(cfg, clock=clk, tick_dt=1.0), clk


@pytest.mark.slow
class TestClosedLoopOverload:
    def test_p50_unaffected_at_half_load(self):
        h, _ = _harness(max_pending=128)
        pt = h.run_point(0.5)
        # under-offered: every request executes in the tick it arrived
        # (zero simulated queueing), nothing sheds
        assert pt["latency_s"]["p50"] == pytest.approx(0.0)
        assert pt["admission"]["shed_examples"] == 0
        assert pt["admission"]["executed_examples"] \
            == pt["admission"]["offered_examples"]

    def test_p99_bounded_under_2x_overload(self):
        h, _ = _harness(max_pending=128)
        pt = h.run_point(2.0)
        # depth bound = 2 ticks of budget -> a ticket waits at most ~2
        # simulated ticks before executing or shedding; without the bound
        # the oldest ticket would wait ~measure_ticks ticks
        assert pt["admission"]["shed_examples"] > 0
        assert pt["latency_s"]["p99"] <= 3.0
        assert pt["pending_examples"] <= 128

    def test_unbounded_queue_without_admission(self):
        h, _ = _harness(max_pending=None)
        pt = h.run_point(2.0)
        assert pt["admission"]["shed_examples"] == 0
        # 2x offered vs budget: queue grows ~budget/tick through warmup
        # and measurement; latency tail tracks the backlog
        assert pt["pending_examples"] >= 64 * 6
        assert pt["latency_s"]["p99"] > 3.0


# ---------------------------------------------------------------------------
# event→deployed staleness
# ---------------------------------------------------------------------------
class TestStaleness:
    def _cluster(self):
        from repro.configs.weips_ctr import LR_FTRL
        from repro.core.cluster import ClusterConfig, WeiPSCluster
        return WeiPSCluster(LR_FTRL, ClusterConfig(
            num_master=1, num_slave=1, num_replicas=1, num_partitions=2,
            gather_mode="realtime"))

    def test_staleness_matches_scripted_schedule(self):
        """Hand-computable: updates pushed at t=1.0, scatter-applied at
        t=3.5 → every applied record reports staleness 2.5."""
        cl = self._cluster()
        ids = np.arange(8, dtype=np.int64).reshape(2, 4)
        y = np.array([1.0, 0.0], np.float32)
        cl.train_on_batch(ids, y, now=1.0)
        cl.sync_tick(1.0, scatter=False)      # push stamps meta["t"]=1.0
        for sc in cl.scatters:
            sc.poll(now=3.5)
        stale = cl.sync_metrics(3.5)["staleness"]
        assert stale["p50"] == pytest.approx(2.5)
        assert stale["p99"] == pytest.approx(2.5)

    def test_pushed_update_cache_visible_after_poll(self):
        """The staleness metric's 'deployed' endpoint is real: a pushed
        update invalidates the serve cache during the poll, and the NEXT
        predict reflects the new weights."""
        cl = self._cluster()
        ids = np.arange(4, dtype=np.int64).reshape(1, 4)
        p0 = cl.predict(ids)                  # caches (zero) rows
        assert float(p0[0]) == pytest.approx(0.5)   # untrained LR
        for _ in range(30):                   # train the same ids hard
            cl.train_on_batch(ids, np.ones(1, np.float32), now=1.0)
        cl.sync_tick(1.0, scatter=False)
        p_stale = cl.predict(ids)             # not yet deployed: cached
        assert float(p_stale[0]) == pytest.approx(0.5)
        for sc in cl.scatters:
            sc.poll(now=2.0)                  # deploy -> invalidate
        p_fresh = cl.predict(ids)
        assert float(p_fresh[0]) > 0.5
        stale = cl.sync_metrics(2.0)["staleness"]
        assert stale["p99"] == pytest.approx(1.0)

    def test_poll_without_now_records_nothing(self):
        cl = self._cluster()
        ids = np.arange(8, dtype=np.int64).reshape(2, 4)
        cl.train_on_batch(ids, np.ones(2, np.float32), now=1.0)
        cl.sync_tick(1.0, scatter=False)
        for sc in cl.scatters:
            sc.poll()                         # legacy call: no timestamp
        assert all(len(sc.staleness) == 0 for sc in cl.scatters)


# ---------------------------------------------------------------------------
# cache window counters
# ---------------------------------------------------------------------------
class TestCacheWindows:
    def test_serve_cache_window_deltas_and_reset(self):
        c = ServeCache({"w": 2}, max_rows=64)
        ids = np.arange(8, dtype=np.int64)
        c.lookup(ids)                                   # 8 misses
        c.fill(ids, np.ones((8, 2), np.float32))
        c.lookup(ids)                                   # 8 hits
        w1 = c.window_stats()
        assert w1["hits"] == 8 and w1["misses"] == 8
        assert w1["hit_rate"] == pytest.approx(0.5)
        # new window starts empty; lifetime counters are untouched
        w2 = c.window_stats()
        assert w2["hits"] == 0 and w2["misses"] == 0
        assert w2["hit_rate"] == 0.0
        assert c.stats()["hits"] == 8 and c.stats()["misses"] == 8
        c.invalidate(ids[:3])
        w3 = c.window_stats()
        assert w3["invalidated"] == 3 and w3["hits"] == 0

    def test_dense_cache_uniform_stats(self):
        d = DenseCache()
        fetch = lambda: np.zeros(4, np.float32)  # noqa: E731
        d.get("h", (1, 4), 1, fetch)                    # refresh
        d.get("h", (1, 4), 1, fetch)                    # hit
        s = d.stats()
        assert s["hits"] == 1 and s["misses"] == 1
        assert s["hit_rate"] == pytest.approx(0.5)
        w = d.window_stats()
        assert w["hits"] == 1 and w["misses"] == 1
        assert d.window_stats()["hits"] == 0            # window reset
        d.clear()
        assert d.window_stats()["invalidated"] == 1

    def test_admission_and_caches_in_sync_metrics(self):
        """The harness-facing contract: sync_metrics()["serving"] carries
        admission totals, latency percentiles, and uniform per-scenario
        cache stats."""
        from repro.configs.weips_ctr import LR_FTRL
        from repro.core.cluster import ClusterConfig, WeiPSCluster
        cl = WeiPSCluster(LR_FTRL, ClusterConfig(
            num_master=1, num_slave=1, num_replicas=1, num_partitions=2,
            serve_max_pending=8))
        ids = np.arange(4, dtype=np.int64).reshape(1, 4)
        cl.predict(ids)
        reqs = np.repeat(ids, 4, axis=0)      # 4 examples per submit
        for _ in range(4):                    # 16 examples > bound of 8
            cl.serving.submit(reqs)
        cl.serving.flush()
        serving = cl.sync_metrics(0.0)["serving"]
        adm = serving["admission"]
        assert adm["offered_requests"] == 5
        assert adm["executed_requests"] + adm["shed_requests"] == 5
        assert adm["shed_depth_requests"] > 0
        assert set(serving["latency"]) == {"p50", "p99"}
        scn = serving["scenarios"][LR_FTRL.name]
        for key in ("cache", "dense_cache"):
            assert {"hits", "misses", "hit_rate",
                    "invalidated"} <= set(scn[key])
        assert scn["admission"]["offered_requests"] == 5
