"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (<=2 layers, d_model<=512, <=4 experts) runs one forward and
one train step on CPU, asserting output shapes and no NaNs. Decode archs
also run one serve step."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import (decode_step, forward, init_cache, init_params,
                          precompute_cross_cache)
from repro.training import init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.has_encoder_context:
        batch["enc_context"] = jax.random.normal(
            key, (B, cfg.encoder_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, metrics = forward(params, cfg, batch["tokens"],
                              enc_context=batch.get("enc_context"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not jnp.isnan(logits[..., :cfg.vocab_size]).any()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    state = init_train_state(cfg, key)
    step = make_train_step(cfg, donate=False)   # old state inspected below
    new_state, metrics = step(state, _batch(cfg, key))
    assert float(metrics["loss"]) > 0 and not jnp.isnan(metrics["loss"])
    assert int(new_state.step) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     new_state.params, state.params))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_decode_step(arch):
    cfg = reduced(get_config(arch))
    if not cfg.supports_decode:
        pytest.skip("encoder-only")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    cache = init_cache(cfg, B, 16, dtype=jnp.float32)
    if cfg.has_encoder_context:
        enc = jax.random.normal(key, (B, cfg.encoder_len, cfg.d_model))
        cache = precompute_cross_cache(params, cfg, cache, enc)
    logits, new_cache = decode_step(params, cfg, cache,
                                    jnp.zeros((B, 1), jnp.int32),
                                    jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert not jnp.isnan(logits[..., :cfg.vocab_size]).any()
