"""Streaming synchronization behaviour: gather modes, dedup, idempotent
last-writer-wins application, deletes, eventual consistency."""

import numpy as np
import pytest

from repro.core import (MasterShard, PartitionedQueue, Record, RoutingPlan,
                        SlaveShard, make_transform)
from repro.core.streaming import Collector, Gatherer, Pusher, Scatter
from repro.optim import get_optimizer


def _mk(num_master=1, num_slave=2, parts=4, codec="identity",
        optimizer="ftrl"):
    plan = RoutingPlan(num_master, num_slave, parts)
    opt = get_optimizer(optimizer)
    queue = PartitionedQueue(parts)
    transform = make_transform(codec, opt)
    master = MasterShard(0, {"w": 4}, opt)
    col = Collector()
    master.collector = col
    slaves = [SlaveShard(i, {"w": 4}) for i in range(num_slave)]
    scatters = [Scatter(s, queue, plan) for s in slaves]
    pusher = Pusher(master, queue, plan, transform)
    return plan, queue, master, col, slaves, scatters, pusher, transform


def test_gather_modes():
    g = Gatherer("realtime")
    g.offer([("w", np.array([1, 2, 3]), "upsert")])
    assert g.ready(0.0)

    g = Gatherer("threshold", threshold=5)
    g.offer([("w", np.array([1, 2, 3]), "upsert")])
    assert not g.ready(0.0)
    g.offer([("w", np.array([4, 5]), "upsert")])
    assert g.ready(0.0)

    g = Gatherer("period", period=10.0)
    g.offer([("w", np.array([1]), "upsert")])
    assert not g.ready(5.0)
    assert g.ready(10.0)


def test_gather_dedup_ratio():
    """Repeated IDs within a window are pushed once (paper's >=90 %
    repetition => ~10x bandwidth saving)."""
    g = Gatherer("period", period=1.0)
    for _ in range(10):
        g.offer([("w", np.array([1, 2, 3, 4]), "upsert")])
    out = g.flush(1.0)
    assert len(out[("w", "upsert")]) == 4
    assert g.stats.raw_ids == 40 and g.stats.pushed_ids == 4
    assert g.stats.dedup_ratio == pytest.approx(0.9)


def test_end_to_end_eventual_consistency():
    plan, queue, master, col, slaves, scatters, pusher, transform = _mk()
    rng = np.random.default_rng(0)
    gatherer = Gatherer("realtime")
    for step in range(20):
        ids = rng.integers(0, 1000, size=16).astype(np.int64)
        grads = rng.normal(size=(16, 4)).astype(np.float32)
        master.push_grad("w", ids, grads)
        gatherer.offer(col.drain())
        pusher.push(gatherer.flush(step), now=float(step))
        for sc in scatters:
            sc.poll()
    # quiescence: every slave row equals transform(master row)
    all_ids = master.tables["w"].all_ids()
    w, slots = master.tables["w"].gather(all_ids)
    serve = transform.serve_values(w, slots)
    owner = plan.slave_shard(all_ids)
    for sid, slave in enumerate(slaves):
        mask = owner == sid
        got = slave.lookup("w", all_ids[mask])
        np.testing.assert_allclose(got, serve[mask], rtol=1e-5, atol=1e-6)


def test_idempotent_last_writer_wins():
    """Replaying a stale record never overwrites a newer value."""
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk()
    ids = np.array([7], dtype=np.int64)
    master.push_grad("w", ids, np.ones((1, 4), np.float32))
    g = Gatherer("realtime"); g.offer(col.drain())
    pusher.push(g.flush(0), now=0.0)
    master.push_grad("w", ids, np.ones((1, 4), np.float32))
    g.offer(col.drain())
    pusher.push(g.flush(1), now=1.0)
    for sc in scatters:
        sc.poll()
    sid = int(plan.slave_shard(ids)[0])
    after_two = slaves[sid].lookup("w", ids).copy()
    # replay the whole queue from offset 0 (at-least-once redelivery)
    replay = Scatter(slaves[sid], queue, plan,
                     offsets={p: 0 for p in range(queue.num_partitions)})
    replay.poll()
    np.testing.assert_array_equal(slaves[sid].lookup("w", ids), after_two)
    assert slaves[sid].skipped_records > 0


def test_delete_propagates():
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk()
    ids = np.array([1, 2, 3], dtype=np.int64)
    master.push_grad("w", ids, np.ones((3, 4), np.float32))
    g = Gatherer("realtime"); g.offer(col.drain())
    pusher.push(g.flush(0), now=0.0)
    for sc in scatters:
        sc.poll()
    master.delete_rows("w", np.array([2], dtype=np.int64))
    g.offer(col.drain())
    pusher.push(g.flush(1), now=1.0)
    for sc in scatters:
        sc.poll()
    sid = int(plan.slave_shard(np.array([2]))[0])
    assert len(slaves[sid].tables["w"]) >= 0
    np.testing.assert_array_equal(
        slaves[sid].lookup("w", np.array([2], dtype=np.int64)),
        np.zeros((1, 4), np.float32))


def test_partition_selective_consumption():
    """A slave's scatter only reads its own partitions (paper §4.1.4)."""
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk(
        num_slave=2, parts=4)
    assert scatters[0].consumer.partitions == [0, 2]
    assert scatters[1].consumer.partitions == [1, 3]


CODECS = ("identity", "cast16", "int8")


@pytest.mark.parametrize("codec", CODECS)
def test_codec_roundtrip_through_queue(codec):
    """Every registered codec survives encode → Record → partitioned
    queue → ``decode_record`` within its error bound."""
    from repro.core import decode_record
    w = (np.random.default_rng(1).normal(size=(17, 8)) * 3).astype(
        np.float32)
    t = make_transform(codec)
    q = PartitionedQueue(2)
    q.produce(0, Record(group="w", op="upsert",
                        ids=np.arange(17, dtype=np.int64),
                        payload=t.encode(w, {}), seq=0, producer=0,
                        meta={"codec": t.name}))
    (rec,), _ = q.consume(0, 0)
    got = decode_record(rec)
    if codec == "identity":
        np.testing.assert_array_equal(got, w)
    elif codec == "cast16":
        np.testing.assert_allclose(got, w, rtol=1e-3, atol=1e-4)
    else:
        bound = np.abs(w).max(axis=-1, keepdims=True) / 254.0 + 1e-6
        assert np.all(np.abs(got - w) <= bound)


@pytest.mark.parametrize("codec", CODECS)
def test_pallas_numpy_backends_bit_compatible(codec):
    """Decoded slave weights are bit-identical between the numpy codec
    backend and the pallas delta-codec kernel path (interpret mode
    off-TPU) through the full push→queue→scatter spine."""
    decoded = {}
    for backend in ("numpy", "pallas"):
        plan = RoutingPlan(1, 2, 4)
        opt = get_optimizer("ftrl")
        queue = PartitionedQueue(4)
        master = MasterShard(0, {"w": 8}, opt)
        col = Collector()
        master.collector = col
        slaves = [SlaveShard(i, {"w": 8}, codec_backend=backend)
                  for i in range(2)]
        scatters = [Scatter(s, queue, plan) for s in slaves]
        pusher = Pusher(master, queue, plan,
                        make_transform(codec, opt, backend=backend))
        rng = np.random.default_rng(3)
        for step in range(3):
            ids = rng.integers(0, 500, size=64).astype(np.int64)
            grads = rng.normal(size=(64, 8)).astype(np.float32)
            master.push_grad("w", ids, grads)
            g = Gatherer("realtime")
            g.offer(col.drain())
            pusher.push(g.flush(step), now=float(step))
        for sc in scatters:
            sc.poll()
        all_ids = np.sort(master.tables["w"].all_ids())
        decoded[backend] = np.concatenate(
            [s.lookup("w", all_ids) for s in slaves], axis=0)
    np.testing.assert_array_equal(decoded["numpy"], decoded["pallas"])


def test_batched_scatter_lww_within_poll():
    """Overlapping ids across records inside ONE poll resolve
    last-writer-wins by arrival order — identical to sequential apply —
    and stale redeliveries in later polls are skipped."""
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk(
        num_slave=1, parts=1)
    ids = np.array([5, 6], dtype=np.int64)

    def rec(seq, fill):
        return Record(group="w", op="upsert", ids=ids,
                      payload={"values": np.full((2, 4), fill, np.float32)},
                      seq=seq, producer=0, meta={"codec": "identity"})

    queue.produce(0, rec(0, 1.0))
    queue.produce(0, rec(1, 2.0))
    assert scatters[0].poll() == 2
    np.testing.assert_array_equal(slaves[0].lookup("w", ids),
                                  np.full((2, 4), 2.0, np.float32))
    queue.produce(0, rec(0, 1.0))            # stale redelivery
    assert scatters[0].poll() == 0
    np.testing.assert_array_equal(slaves[0].lookup("w", ids),
                                  np.full((2, 4), 2.0, np.float32))
    assert slaves[0].skipped_records == 1


def test_cross_partition_seq_streams_independent():
    """LWW staleness is keyed per (group, producer, partition): a flush
    touching only partition 0 must not mark partition 1's in-flight
    lower-seq records (disjoint ids) stale."""
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk(
        num_slave=1, parts=2)

    def rec(seq, part, ids, fill):
        return Record(group="w", op="upsert", ids=ids,
                      payload={"values": np.full((len(ids), 4), fill,
                                                 np.float32)},
                      seq=seq, producer=0,
                      meta={"codec": "identity", "partition": part})

    a, b = np.array([1], np.int64), np.array([2], np.int64)
    queue.produce(0, rec(0, 0, a, 1.0))     # flush 0 touched both parts
    queue.produce(1, rec(0, 1, b, 2.0))
    queue.produce(0, rec(1, 0, a, 3.0))     # flush 1 touched only part 0
    # consumer drains partition 0 first (seq 0 then 1), then partition 1's
    # seq-0 record — which must still apply
    assert scatters[0].poll() == 3
    np.testing.assert_array_equal(slaves[0].lookup("w", a),
                                  np.full((1, 4), 3.0, np.float32))
    np.testing.assert_array_equal(slaves[0].lookup("w", b),
                                  np.full((1, 4), 2.0, np.float32))
    assert slaves[0].skipped_records == 0


def test_pipeline_does_not_override_slave_codec_backend():
    """Producer and consumer codec backends are independent: wiring a
    numpy-transform pipeline must not clobber a slave's configured
    decode backend."""
    from repro.core.streaming import SyncPipeline
    opt = get_optimizer("ftrl")
    master = MasterShard(0, {"w": 4}, opt)
    slave = SlaveShard(0, {"w": 4}, codec_backend="pallas")
    SyncPipeline(master, [slave], PartitionedQueue(4), RoutingPlan(1, 1, 4),
                 make_transform("int8", opt, backend="numpy"))
    assert slave.codec_backend == "pallas"


def test_batched_scatter_upsert_then_delete_ordering():
    """A delete arriving after an upsert for the same id within ONE poll
    must win — the deferred coalesced scatter may not resurrect rows the
    delete evicted (matches sequential apply)."""
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk(
        num_slave=1, parts=1)
    ids = np.array([9], dtype=np.int64)
    queue.produce(0, Record(group="w", op="upsert", ids=ids,
                            payload={"values": np.ones((1, 4), np.float32)},
                            seq=0, producer=0, meta={"codec": "identity"}))
    queue.produce(0, Record(group="w", op="delete", ids=ids, payload={},
                            seq=1, producer=0, meta={"codec": "identity"}))
    assert scatters[0].poll() == 2
    assert len(slaves[0].tables["w"]) == 0
    np.testing.assert_array_equal(slaves[0].lookup("w", ids),
                                  np.zeros((1, 4), np.float32))


def test_vectorized_push_chunking_consistency():
    """Partition-chunked records (small max_ids_per_record) carry
    row-aligned payload slices: slaves converge to the same state."""
    plan, queue, master, col, slaves, scatters, pusher, transform = _mk()
    pusher.max_ids_per_record = 3
    ids = np.arange(100, dtype=np.int64)
    master.push_grad("w", ids, np.ones((100, 4), np.float32))
    g = Gatherer("realtime")
    g.offer(col.drain())
    n = pusher.push(g.flush(0), now=0.0)
    assert n > len(np.unique(plan.partition(ids)))   # chunking kicked in
    for sc in scatters:
        sc.poll()
    w, slots = master.tables["w"].gather(ids)
    serve = transform.serve_values(w, slots)
    owner = plan.slave_shard(ids)
    for sid, slave in enumerate(slaves):
        mask = owner == sid
        np.testing.assert_allclose(slave.lookup("w", ids[mask]),
                                   serve[mask], rtol=1e-5, atol=1e-6)


def test_ftrl_heterogeneous_parameters():
    """Slave receives derived w, not (z, n) — and they differ."""
    plan, queue, master, col, slaves, scatters, pusher, transform = _mk(
        optimizer="ftrl")
    ids = np.array([42], dtype=np.int64)
    for step in range(5):
        master.push_grad("w", ids, np.full((1, 4), 2.0, np.float32))
        g = Gatherer("realtime"); g.offer(col.drain())
        pusher.push(g.flush(step), now=float(step))
    for sc in scatters:
        sc.poll()
    w_master, slots = master.tables["w"].gather(ids)
    sid = int(plan.slave_shard(ids)[0])
    w_slave = slaves[sid].lookup("w", ids)
    # slave value equals FTRL weights derived from z,n
    np.testing.assert_allclose(w_slave, transform.serve_values(
        w_master, slots), rtol=1e-5)
    assert not np.allclose(slots["z"], w_slave)     # z != w (heterogeneous)
