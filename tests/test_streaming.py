"""Streaming synchronization behaviour: gather modes, dedup, idempotent
last-writer-wins application, deletes, eventual consistency."""

import numpy as np
import pytest

from repro.core import (MasterShard, PartitionedQueue, Record, RoutingPlan,
                        SlaveShard, make_transform)
from repro.core.streaming import Collector, Gatherer, Pusher, Scatter
from repro.optim import get_optimizer


def _mk(num_master=1, num_slave=2, parts=4, codec="identity",
        optimizer="ftrl"):
    plan = RoutingPlan(num_master, num_slave, parts)
    opt = get_optimizer(optimizer)
    queue = PartitionedQueue(parts)
    transform = make_transform(codec, opt)
    master = MasterShard(0, {"w": 4}, opt)
    col = Collector()
    master.collector = col
    slaves = [SlaveShard(i, {"w": 4}) for i in range(num_slave)]
    scatters = [Scatter(s, queue, plan) for s in slaves]
    pusher = Pusher(master, queue, plan, transform)
    return plan, queue, master, col, slaves, scatters, pusher, transform


def test_gather_modes():
    g = Gatherer("realtime")
    g.offer([("w", np.array([1, 2, 3]), "upsert")])
    assert g.ready(0.0)

    g = Gatherer("threshold", threshold=5)
    g.offer([("w", np.array([1, 2, 3]), "upsert")])
    assert not g.ready(0.0)
    g.offer([("w", np.array([4, 5]), "upsert")])
    assert g.ready(0.0)

    g = Gatherer("period", period=10.0)
    g.offer([("w", np.array([1]), "upsert")])
    assert not g.ready(5.0)
    assert g.ready(10.0)


def test_gather_dedup_ratio():
    """Repeated IDs within a window are pushed once (paper's >=90 %
    repetition => ~10x bandwidth saving)."""
    g = Gatherer("period", period=1.0)
    for _ in range(10):
        g.offer([("w", np.array([1, 2, 3, 4]), "upsert")])
    out = g.flush(1.0)
    assert len(out[("w", "upsert")]) == 4
    assert g.stats.raw_ids == 40 and g.stats.pushed_ids == 4
    assert g.stats.dedup_ratio == pytest.approx(0.9)


def test_end_to_end_eventual_consistency():
    plan, queue, master, col, slaves, scatters, pusher, transform = _mk()
    rng = np.random.default_rng(0)
    gatherer = Gatherer("realtime")
    for step in range(20):
        ids = rng.integers(0, 1000, size=16).astype(np.int64)
        grads = rng.normal(size=(16, 4)).astype(np.float32)
        master.push_grad("w", ids, grads)
        gatherer.offer(col.drain())
        pusher.push(gatherer.flush(step), now=float(step))
        for sc in scatters:
            sc.poll()
    # quiescence: every slave row equals transform(master row)
    all_ids = master.tables["w"].all_ids()
    w, slots = master.tables["w"].gather(all_ids)
    serve = transform.serve_values(w, slots)
    owner = plan.slave_shard(all_ids)
    for sid, slave in enumerate(slaves):
        mask = owner == sid
        got = slave.lookup("w", all_ids[mask])
        np.testing.assert_allclose(got, serve[mask], rtol=1e-5, atol=1e-6)


def test_idempotent_last_writer_wins():
    """Replaying a stale record never overwrites a newer value."""
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk()
    ids = np.array([7], dtype=np.int64)
    master.push_grad("w", ids, np.ones((1, 4), np.float32))
    g = Gatherer("realtime"); g.offer(col.drain())
    pusher.push(g.flush(0), now=0.0)
    master.push_grad("w", ids, np.ones((1, 4), np.float32))
    g.offer(col.drain())
    pusher.push(g.flush(1), now=1.0)
    for sc in scatters:
        sc.poll()
    sid = int(plan.slave_shard(ids)[0])
    after_two = slaves[sid].lookup("w", ids).copy()
    # replay the whole queue from offset 0 (at-least-once redelivery)
    replay = Scatter(slaves[sid], queue, plan,
                     offsets={p: 0 for p in range(queue.num_partitions)})
    replay.poll()
    np.testing.assert_array_equal(slaves[sid].lookup("w", ids), after_two)
    assert slaves[sid].skipped_records > 0


def test_delete_propagates():
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk()
    ids = np.array([1, 2, 3], dtype=np.int64)
    master.push_grad("w", ids, np.ones((3, 4), np.float32))
    g = Gatherer("realtime"); g.offer(col.drain())
    pusher.push(g.flush(0), now=0.0)
    for sc in scatters:
        sc.poll()
    master.delete_rows("w", np.array([2], dtype=np.int64))
    g.offer(col.drain())
    pusher.push(g.flush(1), now=1.0)
    for sc in scatters:
        sc.poll()
    sid = int(plan.slave_shard(np.array([2]))[0])
    assert len(slaves[sid].tables["w"]) >= 0
    np.testing.assert_array_equal(
        slaves[sid].lookup("w", np.array([2], dtype=np.int64)),
        np.zeros((1, 4), np.float32))


def test_partition_selective_consumption():
    """A slave's scatter only reads its own partitions (paper §4.1.4)."""
    plan, queue, master, col, slaves, scatters, pusher, _ = _mk(
        num_slave=2, parts=4)
    assert scatters[0].consumer.partitions == [0, 2]
    assert scatters[1].consumer.partitions == [1, 3]


def test_ftrl_heterogeneous_parameters():
    """Slave receives derived w, not (z, n) — and they differ."""
    plan, queue, master, col, slaves, scatters, pusher, transform = _mk(
        optimizer="ftrl")
    ids = np.array([42], dtype=np.int64)
    for step in range(5):
        master.push_grad("w", ids, np.full((1, 4), 2.0, np.float32))
        g = Gatherer("realtime"); g.offer(col.drain())
        pusher.push(g.flush(step), now=float(step))
    for sc in scatters:
        sc.poll()
    w_master, slots = master.tables["w"].gather(ids)
    sid = int(plan.slave_shard(ids)[0])
    w_slave = slaves[sid].lookup("w", ids)
    # slave value equals FTRL weights derived from z,n
    np.testing.assert_allclose(w_slave, transform.serve_values(
        w_master, slots), rtol=1e-5)
    assert not np.allclose(slots["z"], w_slave)     # z != w (heterogeneous)
