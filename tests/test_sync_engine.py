"""ModelSyncEngine: full-model streaming sync for the architecture zoo —
eventual consistency to codec error bounds, expert-granular sync, dedup,
delta-threshold bandwidth optimization."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.sync_engine import ModelSyncEngine, SyncConfig
from repro.models import decode_step, init_cache
from repro.training import init_train_state, make_train_step


def _train_and_sync(arch, sync_cfg, steps=6, batch=4, seq=32, seed=0):
    cfg = reduced(get_config(arch))
    state = init_train_state(cfg, jax.random.PRNGKey(seed))
    step = make_train_step(cfg)
    engine = ModelSyncEngine(cfg, state.params, sync_cfg)
    rng = np.random.default_rng(seed)
    for t in range(steps):
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                             jnp.int32)
        b = {"tokens": tokens}
        if cfg.has_encoder_context:
            b["enc_context"] = jnp.zeros((batch, cfg.encoder_len,
                                          cfg.d_model))
        state, metrics = step(state, b)
        host = {}
        if "expert_counts_per_layer" in metrics:
            host["expert_counts_per_layer"] = jax.tree.map(
                np.asarray, metrics["expert_counts_per_layer"])
        engine.collect_step(np.asarray(tokens), host)
        engine.tick(state.params, now=t * 0.5)
    engine.tick(state.params, now=1e9)       # final flush
    return cfg, state, engine


@pytest.mark.parametrize("codec,bound", [
    ("identity", 1e-6), ("cast16", 2e-3), ("int8", 2e-2)])
def test_eventual_consistency_codec_bounds(codec, bound):
    cfg, state, engine = _train_and_sync(
        "qwen2-1.5b", SyncConfig(gather_mode="period", period=1.0,
                                 codec=codec))
    assert engine.replicas[0].staleness(state.params) < bound


def test_moe_expert_granular_sync():
    cfg, state, engine = _train_and_sync(
        "granite-moe-3b-a800m",
        SyncConfig(gather_mode="period", period=1.0, codec="identity"))
    assert engine.replicas[0].staleness(state.params) < 1e-5
    # expert leaves were classified and synced as experts, not dense
    expert_paths = [p for p, k in engine.kinds.items() if k == "experts"]
    assert len(expert_paths) >= 3       # w_gate/w_up/w_down at least


def test_serve_params_usable_for_decode():
    cfg, state, engine = _train_and_sync(
        "qwen2-1.5b", SyncConfig(gather_mode="period", period=1.0,
                                 codec="cast16"))
    sp = engine.replicas[0].device_params(dtype="float32")
    cache = init_cache(cfg, 2, 8, dtype=jnp.float32)
    logits, _ = decode_step(sp, cfg, cache, jnp.zeros((2, 1), jnp.int32),
                            jnp.zeros((2,), jnp.int32))
    assert not jnp.isnan(logits[..., :cfg.vocab_size]).any()


def test_period_mode_dedups_dense_pushes():
    """10 steps with one flush -> each dense tensor pushed once, not 10x
    (the paper's repetition/dedup effect at tensor granularity)."""
    cfg, state, engine = _train_and_sync(
        "qwen2-1.5b", SyncConfig(gather_mode="period", period=1e6,
                                 codec="cast16"), steps=10)
    assert engine.gatherer.stats.dedup_ratio > 0.8
    assert engine._flushes == 1


def test_codec_bandwidth_ordering():
    _, _, e32 = _train_and_sync("qwen2-1.5b", SyncConfig(
        gather_mode="period", period=1.0, codec="identity"), steps=4)
    _, _, e16 = _train_and_sync("qwen2-1.5b", SyncConfig(
        gather_mode="period", period=1.0, codec="cast16"), steps=4)
    _, _, e8 = _train_and_sync("qwen2-1.5b", SyncConfig(
        gather_mode="period", period=1.0, codec="int8"), steps=4)
    assert e8.pushed_bytes < e16.pushed_bytes < e32.pushed_bytes


def test_delta_threshold_skips_unchanged():
    """Beyond-paper: tensors whose relative change is below the threshold
    are skipped; a large threshold skips almost everything after the first
    full push, and the skipped tensors are still eventually refreshed."""
    sync = SyncConfig(gather_mode="period", period=1.0, codec="identity",
                      delta_threshold=1e9, full_refresh_every=0)
    cfg, state, engine = _train_and_sync("qwen2-1.5b", sync, steps=6)
    assert engine.skipped_dense > 0
    # with full refresh every flush, nothing stays stale
    sync2 = SyncConfig(gather_mode="period", period=1.0, codec="identity",
                       delta_threshold=1e9, full_refresh_every=1)
    cfg2, state2, engine2 = _train_and_sync("qwen2-1.5b", sync2, steps=6)
    assert engine2.replicas[0].staleness(state2.params) < 1e-6
