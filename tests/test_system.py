"""End-to-end behaviour of the full WeiPS system (paper workflow):
online learning on a drifting click stream with second-level deployment,
consistency between the training and serving planes, and learning progress
visible through progressive validation."""

import numpy as np
import pytest

from repro.configs.weips_ctr import CTR_CONFIGS, DNN_ADAM, FM_FTRL, LR_FTRL
from repro.core import ClusterConfig, WeiPSCluster
from repro.data import ClickStream


@pytest.mark.parametrize("model", [
    "weips-lr-ftrl", "weips-fm-ftrl", "weips-fm-sgd", "weips-dnn-adam",
])
def test_online_learning_improves(model):
    cfg = CTR_CONFIGS[model]
    cl = WeiPSCluster(cfg, ClusterConfig(
        num_master=2, num_slave=2, num_replicas=1, num_partitions=4))
    stream = ClickStream(feature_space=1 << 12, fields=cfg.fields, seed=0)
    for i in range(40):
        ids, y = stream.batch(128)
        cl.train_on_batch(ids, y, now=i * 0.1)
        cl.sync_tick(i * 0.1)
    early = np.mean([p.values["logloss"] for p in cl.validator.history[:5]])
    late = np.mean([p.values["logloss"] for p in cl.validator.history[-5:]])
    assert late < early, f"{model}: no learning progress ({early}->{late})"


def test_training_and_serving_agree_after_sync():
    """Fusion consistency: the predictor (slave path) and trainer (master
    path) produce the same predictions once the stream quiesces."""
    cl = WeiPSCluster(FM_FTRL, ClusterConfig(
        num_master=3, num_slave=2, num_replicas=2, num_partitions=4))
    stream = ClickStream(feature_space=1 << 12, fields=FM_FTRL.fields)
    for i in range(20):
        ids, y = stream.batch(64)
        cl.train_on_batch(ids, y, now=float(i))
        cl.sync_tick(float(i))
    ids, _ = stream.batch(64)
    p_serve = cl.predict(ids)
    rows, _, _ = cl._pull_rows(ids)
    import jax.numpy as jnp
    p_train = np.asarray(cl._predict(
        {k: jnp.asarray(v) for k, v in rows.items()},
        {k: jnp.asarray(v) for k, v in cl.dense.items()}))
    np.testing.assert_allclose(p_serve, p_train, rtol=1e-4, atol=1e-5)


def test_second_level_deployment_lag():
    """With realtime gather, serving lag is bounded by one tick (the
    paper's second-level deployment claim, in simulated seconds)."""
    cl = WeiPSCluster(LR_FTRL, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2,
        gather_mode="realtime"))
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    tick = 0.2
    for i in range(10):
        ids, y = stream.batch(32)
        cl.train_on_batch(ids, y, now=i * tick)
        cl.sync_tick(i * tick)
    m = cl.sync_metrics(now=9 * tick)
    assert m["sync_lag_seconds"] <= tick + 1e-9


def test_gather_mode_bandwidth_vs_lag_tradeoff():
    """Period gather trades lag for bandwidth (dedup): longer period ->
    fewer bytes pushed, higher dedup ratio."""
    def run(mode, period):
        cl = WeiPSCluster(LR_FTRL, ClusterConfig(
            num_master=2, num_slave=1, num_replicas=1, num_partitions=2,
            gather_mode=mode, gather_period=period))
        stream = ClickStream(feature_space=1 << 10,
                             fields=LR_FTRL.fields, seed=1)
        now = 0.0
        for i in range(30):
            ids, y = stream.batch(64)
            cl.train_on_batch(ids, y, now=now)
            cl.sync_tick(now)
            now += 0.1
        cl.sync_tick(now + period + 0.1)  # final flush
        return cl.sync_metrics(now)

    rt = run("realtime", 0.0)
    slow = run("period", 1.0)
    assert slow["pushed_bytes"] < rt["pushed_bytes"]
    assert slow["dedup_ratio"] > rt["dedup_ratio"]


def test_feature_expiry_streams_deletes():
    cl = WeiPSCluster(LR_FTRL, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2,
        feature_ttl_steps=2))
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields)
    ids0, y0 = stream.batch(32)
    cl.train_on_batch(ids0, y0, now=0.0)
    cl.sync_tick(0.0)
    # many steps with different features -> originals expire
    for i in range(1, 8):
        ids, y = stream.batch(32)
        cl.train_on_batch(ids, y, now=float(i))
    n_expired = cl.expire_features(now=8.0)
    cl.sync_tick(8.0)
    assert n_expired > 0
    total_rows = sum(len(m.tables["w"]) for m in cl.masters)
    assert total_rows < 32 * LR_FTRL.fields * 8     # bounded model size
