"""The online training plane (src/repro/training/): pipeline end-to-end
learning, pow2 bucketed train steps, multi-scenario registry isolation,
admission-gated row creation, backpressure, the streaming evaluator, and
the train→metric→degrade loop."""

import dataclasses

import numpy as np
import pytest

from repro.configs.weips_ctr import DNN_ADAM, FM_FTRL, FM_SGD, LR_FTRL
from repro.core import ClusterConfig, WeiPSCluster
from repro.core.monitor import StreamingEvaluator, auc, logloss
from repro.data import ClickStream

CC = dict(num_master=2, num_slave=2, num_replicas=1, num_partitions=4)


# ---------------------------------------------------------------------------
# pipeline end-to-end
# ---------------------------------------------------------------------------
def test_pipeline_end_to_end_learns_and_serves():
    """stream → join → train → sync → predict: the joined (windowed)
    labels are enough to learn from, and the result serves."""
    cl = WeiPSCluster(FM_FTRL, ClusterConfig(**CC, join_window=2.0))
    pipe = cl.make_train_pipeline()
    stream = ClickStream(feature_space=1 << 12, fields=FM_FTRL.fields,
                         feedback_delay=0.5, signal_scale=0.8, seed=0)
    now = 0.0
    for _ in range(50):
        pipe.ingest(stream.events_batch(128, now))
        cl.train_scheduler.tick(now)
        cl.sync_tick(now)
        now += 0.5
    cl.train_scheduler.flush(now + 10)
    cl.sync_tick(now + 10)
    scn = cl.training.scenario()
    assert scn.step > 20
    hist = [p.values["logloss"] for p in scn.validator.history]
    assert np.mean(hist[-5:]) < np.mean(hist[:5])
    # what was learned online serves through the serving plane
    ids, y = stream.batch(1024)
    assert auc(y, cl.predict(ids)) > 0.6


def test_pipeline_buckets_bound_compiled_shapes():
    """Ragged drains train through pow2 buckets: a handful of compiled
    shapes, padding accounted, metrics unaffected by the zero-weight
    padding rows."""
    cl = WeiPSCluster(LR_FTRL, ClusterConfig(
        **CC, join_window=0.5, train_buckets=(64, 128, 256)))
    pipe = cl.make_train_pipeline()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields,
                         seed=1, feedback_delay=0.2)
    rng = np.random.default_rng(0)
    now = 0.0
    for _ in range(30):
        pipe.ingest(stream.events_batch(int(rng.integers(40, 200)), now))
        cl.train_scheduler.tick(now)
        now += 1.0
    cl.train_scheduler.flush(now + 5)
    scn = cl.training.scenario()
    assert scn.stats.batches > 0
    assert set(scn.stats.bucket_counts) <= {64, 128, 256}
    assert 0.0 < scn.stats.padding_fraction < 0.5
    assert scn.stats.dedup_ratio > 0.3        # Zipfian repetition absorbed


def test_weighted_padding_matches_unpadded_step():
    """A padded bucketed step must push the same updates as the unpadded
    step: weight-0 padding rows contribute nothing."""
    a = WeiPSCluster(FM_FTRL, ClusterConfig(**CC))
    b = WeiPSCluster(FM_FTRL, ClusterConfig(**CC))
    stream = ClickStream(feature_space=1 << 10, fields=FM_FTRL.fields,
                         seed=2)
    ids, y = stream.batch(100)
    a.training.train_batch(a.training.scenario(), ids, y, now=0.0)
    b.training.train_batch(b.training.scenario(), ids, y, now=0.0,
                           bucket=128)
    for g in a.groups:
        for ma, mb in zip(a.masters, b.masters):
            ta, tb = ma.tables[g], mb.tables[g]
            ia = ta.all_ids()
            np.testing.assert_array_equal(np.sort(ia),
                                          np.sort(tb.all_ids()))
            wa, _ = ta.gather(ia)
            wb, _ = tb.gather(ia)
            np.testing.assert_allclose(wa, wb, rtol=1e-5, atol=1e-7)


def test_negative_downsampling_correction_weights():
    """Downsampled negatives carry 1/rate weights; the weighted pCTR on
    the kept sample matches the unsampled stream's (unbiasedness)."""
    from repro.data import SampleJoiner
    rng = np.random.default_rng(0)
    full = SampleJoiner(window=1.0)
    samp = SampleJoiner(window=1.0, neg_sample_rate=0.25, seed=3)
    n = 20_000
    vids = np.arange(n, dtype=np.int64)
    feats = rng.integers(0, 100, size=(n, 4))
    y = rng.random(n) < 0.2
    for j in (full, samp):
        j.offer_exposures(0.0, vids, feats)
        j.offer_feedbacks(0.5, vids[y])
    bf = full.drain_batch(2.0)
    bs = samp.drain_batch(2.0)
    assert samp.negatives_dropped > 0
    assert len(bs) < len(bf)
    assert (bs.weights[bs.labels > 0] == 1.0).all()
    assert (bs.weights[bs.labels <= 0] == 4.0).all()
    ctr_full = bf.labels.mean()
    ctr_weighted = float((bs.weights * bs.labels).sum() / bs.weights.sum())
    assert abs(ctr_weighted - ctr_full) < 0.02


# ---------------------------------------------------------------------------
# multi-scenario registry
# ---------------------------------------------------------------------------
def test_two_scenarios_concurrent_equals_solo():
    """Registry isolation (acceptance): two scenarios training
    concurrently off ONE shared PS reach the same logloss trajectory as
    each trained alone — namespaced groups and per-scenario dense heads
    share infrastructure but never parameters."""
    def batches(seed, n=20):
        s = ClickStream(feature_space=1 << 12, fields=32, seed=seed,
                        signal_scale=0.8)
        return [s.batch(128) for _ in range(n)]

    b1, b2 = batches(11), batches(22)

    solo1 = WeiPSCluster(FM_FTRL, ClusterConfig(**CC))
    for i, (ids, y) in enumerate(b1):
        solo1.train_on_batch(ids, y, now=float(i))

    solo2 = WeiPSCluster(FM_FTRL, ClusterConfig(**CC))
    scn_s = solo2.add_train_scenario(LR_FTRL, name="iso")
    for i, (ids, y) in enumerate(b2):
        solo2.training.train_batch(scn_s, ids, y, now=float(i))

    both = WeiPSCluster(FM_FTRL, ClusterConfig(**CC))
    scn_c = both.add_train_scenario(LR_FTRL, name="iso")
    for i in range(len(b1)):
        both.train_on_batch(*b1[i], now=float(i))
        both.training.train_batch(scn_c, *b2[i], now=float(i))
        both.sync_tick(float(i))

    ll = lambda v: np.array([p.values["logloss"] for p in v.history])
    np.testing.assert_allclose(ll(both.validator), ll(solo1.validator),
                               rtol=1e-6)
    np.testing.assert_allclose(ll(scn_c.validator), ll(scn_s.validator),
                               rtol=1e-6)


def test_isolated_scenario_tables_stream_to_slaves():
    """Namespaced scenario groups ride the same sync stream: after a
    tick the slave tables hold the scenario's serve weights."""
    cl = WeiPSCluster(FM_FTRL, ClusterConfig(**CC))
    scn = cl.add_train_scenario(LR_FTRL, name="iso")
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields,
                         seed=4)
    ids, y = stream.batch(64)
    cl.training.train_batch(scn, ids, y, now=0.0)
    cl.sync_tick(0.0)
    total = sum(len(shard.tables["iso/w"]) for rs in cl.replica_sets
                for shard in rs.replicas[:1])
    assert total == sum(len(m.tables["iso/w"]) for m in cl.masters)
    assert "iso/w" in cl.serving.store_groups


def test_shared_scenario_trains_store_groups():
    """A share_groups scenario (LR head on an FM store) really writes the
    store's own ``w`` — and a non-matching optimizer is rejected."""
    cl = WeiPSCluster(FM_FTRL, ClusterConfig(**CC))
    scn = cl.add_train_scenario(LR_FTRL, name="lr-head",
                                share_groups=True)
    assert scn.group_map == {"w": "w"}
    before = sum(len(m.tables["w"]) for m in cl.masters)
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields,
                         seed=5)
    ids, y = stream.batch(64)
    cl.training.train_batch(scn, ids, y, now=0.0)
    assert sum(len(m.tables["w"]) for m in cl.masters) > before
    with pytest.raises(ValueError):
        cl.add_train_scenario(FM_SGD, name="bad-opt")


def test_train_scenarios_published_to_registry():
    cl = WeiPSCluster(FM_FTRL, ClusterConfig(**CC))
    cl.add_train_scenario(LR_FTRL, name="iso")
    names = cl.scheduler.train_scenarios(FM_FTRL.name)
    assert set(names) == {FM_FTRL.name, "iso"}
    meta = cl.scheduler.train_scenario_meta(FM_FTRL.name, "iso")
    assert meta["groups"] == ["iso/w"]


# ---------------------------------------------------------------------------
# admission, backpressure
# ---------------------------------------------------------------------------
def test_admission_gates_row_creation():
    """min_count=2: ids seen once never allocate PS rows; recurring ids
    do — and training still proceeds."""
    cl = WeiPSCluster(LR_FTRL, ClusterConfig(**CC, feature_min_count=2))
    once = np.arange(1000, 1032, dtype=np.int64).reshape(1, -1)
    twice = np.arange(2000, 2032, dtype=np.int64).reshape(1, -1)
    y = np.ones(1, np.float32)
    cl.train_on_batch(twice, y, now=0.0)
    cl.train_on_batch(np.concatenate([once, twice]),
                      np.ones(2, np.float32), now=1.0)
    rows = np.concatenate([m.tables["w"].all_ids() for m in cl.masters])
    assert np.isin(twice.reshape(-1), rows).all()
    assert not np.isin(once.reshape(-1), rows).any()


def test_backpressure_throttles_then_recovers():
    """Training cannot outrun deployment: while Scatter.lag() exceeds the
    bound the pipeline buffers (and sheds past the cap) instead of
    pushing updates; once the scatter catches up it trains again."""
    cl = WeiPSCluster(LR_FTRL, ClusterConfig(
        num_master=1, num_slave=1, num_replicas=1, num_partitions=2,
        train_max_sync_lag=0, join_window=0.5, train_buffer_cap=256))
    pipe = cl.make_train_pipeline()
    stream = ClickStream(feature_space=1 << 10, fields=LR_FTRL.fields,
                         seed=1)
    t = 0.0
    for _ in range(8):
        pipe.ingest(stream.events_batch(128, t))
        cl.train_on_batch(*stream.batch(8), now=t)
        cl.sync_tick(t, scatter=False)          # lag builds unscattered
        cl.train_scheduler.tick(t)
        t += 1.0
    assert pipe.throttled_ticks == 8
    assert pipe.shed_examples > 0
    assert pipe.buffered <= 256
    m = cl.sync_metrics(t)
    pm = m["training"]["scenarios"][LR_FTRL.name]["pipeline"]
    assert pm["throttled_ticks"] == 8 and pm["shed_examples"] > 0
    steps_before = cl.training.scenario().step
    cl.sync_tick(t)                              # scatter catches up
    cl.train_scheduler.flush(t + 5)
    assert cl.training.scenario().step > steps_before


# ---------------------------------------------------------------------------
# streaming evaluator + downgrade loop
# ---------------------------------------------------------------------------
def test_streaming_evaluator_matches_exact_metrics():
    rng = np.random.default_rng(0)
    ev = StreamingEvaluator(window=100, bins=4096)
    ys, ps = [], []
    for i in range(20):
        y = (rng.random(256) < 0.3).astype(np.float32)
        p = np.clip(rng.random(256), 0.01, 0.99).astype(np.float32)
        p = np.where(y > 0, np.clip(p + 0.1, 0, 0.999), p)
        ev.observe(float(i), i, y, p)
        ys.append(y)
        ps.append(p)
    y_all, p_all = np.concatenate(ys), np.concatenate(ps)
    assert ev.smoothed("logloss") == pytest.approx(logloss(y_all, p_all),
                                                   rel=1e-6)
    assert ev.smoothed("auc") == pytest.approx(auc(y_all, p_all), abs=2e-3)
    assert ev.smoothed("calibration") == pytest.approx(
        p_all.mean() / y_all.mean(), rel=1e-6)
    # windowed: a narrower query only sees the tail
    tail = ev.smoothed("logloss", window=5)
    assert tail == pytest.approx(
        logloss(np.concatenate(ys[-5:]), np.concatenate(ps[-5:])),
        rel=1e-6)


def test_corrupt_stream_trips_downgrade_via_pipeline():
    """The acceptance loop: train through the pipeline, checkpoint, then
    a ClickStream.corrupt() shift collapses the windowed streaming
    logloss and the domino downgrade fires off that signal."""
    cfg = dataclasses.replace(LR_FTRL, ftrl_l1=0.01, ftrl_alpha=0.3)
    cl = WeiPSCluster(cfg, ClusterConfig(
        num_master=2, num_slave=1, num_replicas=1, num_partitions=2,
        downgrade_metric="logloss", downgrade_threshold=0.72,
        downgrade_window=3, join_window=0.4))
    pipe = cl.make_train_pipeline(emit_on_feedback=False)
    stream = ClickStream(feature_space=1 << 8, fields=cfg.fields,
                         signal_scale=1.0, feedback_delay=0.1)
    now = 0.0
    for _ in range(35):
        pipe.ingest(stream.events_batch(128, now))
        cl.train_scheduler.tick(now)
        cl.sync_tick(now)
        now += 0.5
    cl.checkpoint(now)
    assert cl.downgrade_check(now) is None        # healthy
    stream.corrupt(scale=2.0)
    for _ in range(10):
        pipe.ingest(stream.events_batch(128, now))
        cl.train_scheduler.tick(now)
        now += 0.5
    cl.train_scheduler.flush(now)
    assert cl.downgrade_check(now) is not None    # trigger fired
    assert len(cl.downgrader.downgrades) == 1


def test_dnn_scenario_trains_through_pipeline():
    """DNN-Adam (the fixed seed failure) learns through the full
    pipeline path too — dead-ReLU init would show up here as AUC 0.5."""
    dnn = dataclasses.replace(DNN_ADAM, fields=8, embed_dim=4,
                              dnn_hidden=(16,))
    cl = WeiPSCluster(dnn, ClusterConfig(**CC, join_window=0.5))
    pipe = cl.make_train_pipeline(emit_on_feedback=True)
    stream = ClickStream(feature_space=1 << 10, fields=dnn.fields,
                         signal_scale=1.0, feedback_delay=0.2, seed=6)
    now = 0.0
    for _ in range(40):
        pipe.ingest(stream.events_batch(128, now))
        cl.train_scheduler.tick(now)
        cl.sync_tick(now)
        now += 0.5
    cl.train_scheduler.flush(now + 5)
    scn = cl.training.scenario()
    assert scn.evaluator.smoothed("auc", window=10) > 0.55
    assert pipe.joiner.fast_emits > 0
