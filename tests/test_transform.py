"""Model transformation properties: FTRL heterogeneous-parameter derivation
and codec error bounds (hypothesis)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (dev extra)")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (Cast16Transform, Int8Transform, Record, Transform,
                        decode_record, make_transform)
from repro.optim import FTRL

rows = hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=1, max_side=32),
                  elements=st.floats(-100, 100, width=32))


@given(w=rows)
@settings(max_examples=40, deadline=None)
def test_identity_roundtrip(w):
    t = Transform()
    rec = Record("g", "upsert", np.arange(len(w)), t.encode(w, {}), 0, 0,
                 meta={"codec": t.name})
    np.testing.assert_array_equal(decode_record(rec), w)


@given(w=rows)
@settings(max_examples=40, deadline=None)
def test_cast16_error_bound(w):
    t = Cast16Transform()
    rec = Record("g", "upsert", np.arange(len(w)), t.encode(w, {}), 0, 0,
                 meta={"codec": t.name})
    got = decode_record(rec)
    np.testing.assert_allclose(got, w, rtol=1e-3, atol=1e-4)


@given(w=rows)
@settings(max_examples=40, deadline=None)
def test_int8_error_bound(w):
    """Row-wise absmax int8: |err| <= absmax_row / 254 (half a quant step)
    + eps."""
    t = Int8Transform()
    rec = Record("g", "upsert", np.arange(len(w)), t.encode(w, {}), 0, 0,
                 meta={"codec": t.name})
    got = decode_record(rec)
    bound = np.abs(w).max(axis=-1, keepdims=True) / 254.0 + 1e-6
    assert np.all(np.abs(got - w) <= bound + 1e-6)


@given(w=rows)
@settings(max_examples=20, deadline=None)
def test_int8_halves_wire_bytes_vs_cast16(w):
    i8 = Int8Transform().encode(w, {})
    c16 = Cast16Transform().encode(w, {})
    if w.shape[1] >= 8:       # scale overhead amortized
        assert Int8Transform().payload_bytes(i8) < \
            Cast16Transform().payload_bytes(c16)


def test_ftrl_transform_derives_w():
    opt = FTRL(alpha=0.1, beta=1.0, l1=0.5, l2=1.0)
    t = make_transform("identity", opt)
    z = np.array([[3.0, -2.0, 0.1, 0.0]], np.float32)
    n = np.array([[4.0, 1.0, 9.0, 0.0]], np.float32)
    w_stored = np.zeros((1, 4), np.float32)
    got = t.serve_values(w_stored, {"z": z, "n": n})
    want = np.asarray(opt.weights_from(jnp.asarray(z), jnp.asarray(n)))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert got[0, 2] == 0.0          # |z| <= l1 -> sparsified to exactly 0
    assert got[0, 0] != 0.0
